"""Recall@10 vs QPS across the repro.search registry: exact vs flat vs IVF.

One harness, every retrieval backend. Builds a GCD-rotated quantized index
per residual depth (PQ at depth 1, RQ above) and serves the same corpus,
queries, and rotation through each registered searcher:

  * exact     — tiled brute force; the recall oracle and the QPS floor
  * flat_adc  — full ADC scan over the very codes IVF probes (attached to
                the IVF build, so "recall vs flat" isolates probing loss)
  * ivf       — ``nprobe`` sweep: scan work vs recall, the serving knob
  * *_sharded — the row-sharded twins, attached to the same artifacts
                (parity rows in-process; the ``--devices N`` sweep runs a
                forced-host-device subprocess and measures per-device scan
                work vs the replicated backend)

Metrics per row:
  * scan work   — CSR rows scored per query (the hardware-independent cost)
  * QPS         — measured wall-clock throughput of the jit'd search
  * recall@10   — (a) vs the flat ADC scan (isolates probing loss)
                  (b) vs exact MIPS through the registry (end-to-end)
  * compression — corpus f32 bytes / code payload bytes

The sweep ends with the serving pieces unique to this paper + subsystem:
a ``subspace_gcd`` RotationDelta absorbed via ``Searcher.refresh`` (codes
untouched, recall preserved) and a ``search.Engine`` ragged-batch pass
whose compile cache must stay at one executable per (bucket, k, nprobe).

Acceptance (ISSUE 1, carried forward): at ≥0.9 recall@10-vs-flat, PQ scan
work must drop ≥5× vs the flat path. ISSUE 2: RQ depth-2 end-to-end with
exact subspace refresh and better quantization than PQ. ISSUE 4: all
registry backends on one harness; Engine compile cache bounded. ISSUE 5
adds: sharded backends match their replicated twins, and per-device scan
work under ``--devices N`` shrinks ~linearly at unchanged recall@10.

Run:  PYTHONPATH=src python benchmarks/ivf_recall_qps.py [--n 100000]
      PYTHONPATH=src python -m benchmarks.run --only ivf [--fast]
      PYTHONPATH=src python -m benchmarks.run --only ivf --devices 4
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro import rotations, search
from repro.data import synthetic
from repro.index import maintain
from repro.metrics import recall_at_k

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _bench(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def sharded_cell(n: int, dim: int, queries: int, lists: int, subspaces: int,
                 codewords: int, devices: int, nprobe: int = 8) -> dict:
    """The --devices measurement: single vs sharded IVF on a forced-host-
    device mesh (runs inside the worker subprocess ``run`` spawns — must be
    imported only after XLA_FLAGS pins the device count)."""
    assert jax.device_count() >= devices, (
        f"need {devices} devices, have {jax.device_count()}")
    from repro.launch.mesh import make_data_mesh
    mesh = make_data_mesh(devices)

    key = jax.random.PRNGKey(0)
    X = synthetic.sift_like(key, n, dim)
    Q = synthetic.sift_like(jax.random.PRNGKey(1), queries, dim)
    R = rotations.random_rotation(jax.random.PRNGKey(2), dim)
    cfg = search.SearchConfig(
        num_lists=lists, subspaces=subspaces, codewords=codewords,
        nprobe=nprobe, train_size=min(n, 16384))

    ivf_s = search.make("ivf")
    single = ivf_s.build(jax.random.PRNGKey(3), X, R, cfg)
    res_single = ivf_s.search(single, Q, k=10, nprobe=nprobe)
    scan_single = float(np.mean(np.asarray(res_single.scanned)))

    sh_s = search.make("ivf_sharded", mesh=mesh)
    state = search.IVFSharded.attach(single.index, mesh=mesh, nprobe=nprobe)
    res = sh_s.search(state, Q, k=10)
    # measured rows scanned: the sharded result's ``scanned`` psums every
    # shard's valid blocks, so /devices is the per-device share (comparable
    # to the single-device measurement, unlike the static window bound)
    per_dev = float(np.mean(np.asarray(res.scanned))) / devices

    truth = np.argsort(-np.asarray(Q @ X.T), axis=1)[:, :10]
    r_single = recall_at_k(np.asarray(res_single.ids), truth)
    r_sharded = recall_at_k(np.asarray(res.ids), truth)

    # Engine over the sharded backend: compile-cache + recompile-free refresh
    engine = search.Engine(sh_s, state, k=10, nprobe=nprobe, min_bucket=32)
    engine.search(np.asarray(Q))
    compiles = engine.stats()["compiles"]
    G = jax.random.normal(jax.random.PRNGKey(9), (dim, dim))
    learner = rotations.make("subspace_gcd", sub=single.index.quantizer.sub)
    _, delta = learner.update(learner.init_from(single.index.R), G, 2e-3,
                              jax.random.PRNGKey(0))
    engine.refresh(delta)
    post = engine.search(np.asarray(Q))
    return dict(
        devices=devices,
        scan_single=scan_single,
        scan_per_device=float(per_dev),
        reduction_per_device=scan_single / max(float(per_dev), 1.0),
        recall_single=float(r_single),
        recall_sharded=float(r_sharded),
        parity=bool(recall_at_k(np.asarray(res.ids),
                                np.asarray(res_single.ids)) >= 0.999),
        refresh_recompiles=int(engine.stats()["compiles"] - compiles),
        post_refresh_recall=float(
            recall_at_k(np.asarray(post.ids), truth)),
    )


def _run_sharded_cell(devices: int, **kw) -> dict:
    """Spawn ``sharded_cell`` under ``--xla_force_host_platform_device_count``
    (the flag must be set before jax initializes, hence the subprocess)."""
    code = (
        "import os, json\n"
        # append rather than overwrite: inherited platform/memory flags must
        # survive (duplicated flags resolve last-wins in XLA)
        "os.environ['XLA_FLAGS'] = (os.environ.get('XLA_FLAGS', '') + "
        f"' --xla_force_host_platform_device_count={devices}').strip()\n"
        "from benchmarks.ivf_recall_qps import sharded_cell\n"
        f"print('CELL=' + json.dumps(sharded_cell(devices={devices}, "
        + ", ".join(f"{k}={v!r}" for k, v in kw.items()) + ")))\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [_REPO, os.path.join(_REPO, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(
            f"sharded cell failed:\n{out.stdout}\n{out.stderr}")
    line = [l for l in out.stdout.splitlines() if l.startswith("CELL=")][-1]
    return json.loads(line[len("CELL="):])


def run(n: int = 100_000, dim: int = 64, queries: int = 256, lists: int = 256,
        subspaces: int = 16, codewords: int = 256, depths=(1, 2),
        use_kernel: bool = False, verbose: bool = True, devices: int = 1):
    """Sweep the searcher registry × residual depths; returns
    (results dict, claim-check dict). ``devices > 1`` appends the sharded
    scan-work sweep on a forced-host-device subprocess."""
    out = print if verbose else (lambda *a, **k: None)
    key = jax.random.PRNGKey(0)
    X = synthetic.sift_like(key, n, dim)
    Q = synthetic.sift_like(jax.random.PRNGKey(1), queries, dim)
    R = rotations.random_rotation(jax.random.PRNGKey(2), dim)

    results: dict = {}
    checks: dict = {}
    full_probe_recall: dict = {}
    swept = set()

    out("backend,scheme,nprobe,scan_rows,scan_reduction,qps,"
        "recall10_vs_flat,recall10_vs_exact")

    # --- exact backend: the oracle every quantized row is scored against
    exact_s = search.make("exact")
    exact_state = exact_s.build(key, X, R, search.SearchConfig(tile_rows=8192))
    exact_res = exact_s.search(exact_state, Q, k=10)
    exact_ids = np.asarray(exact_res.ids)
    exact_dt = _bench(lambda: exact_s.search(exact_state, Q, k=10).scores)
    out(f"exact,-,-,{n},1.0x,{queries/exact_dt:.0f},1.000,1.000")
    swept.add("exact")

    # --- streaming exact twin: the same oracle past HBM scale; its
    # double-buffered tile merge must be bit-identical to the resident scan
    stream_s = search.make("exact_stream")
    stream_state = stream_s.build(key, X, R,
                                  search.SearchConfig(tile_rows=8192))
    stream_res = stream_s.search(stream_state, Q, k=10)
    stream_dt = _bench(lambda: stream_s.search(stream_state, Q, k=10).scores)
    stream_exact = bool(np.array_equal(np.asarray(stream_res.ids), exact_ids))
    out(f"exact_stream,-,-,{n},1.0x,{queries/stream_dt:.0f},"
        f"{1.0 if stream_exact else 0.0:.3f},"
        f"{1.0 if stream_exact else 0.0:.3f}")
    swept.add("exact_stream")
    checks["streaming_matches_exact"] = stream_exact
    results["exact_stream"] = dict(qps=queries / stream_dt,
                                   bit_identical=stream_exact)

    ivf_s = search.make("ivf")
    flat_s = search.make("flat_adc")

    for depth in depths:
        name = "pq" if depth == 1 else f"rq{depth}"
        cfg = search.SearchConfig(
            num_lists=lists, subspaces=subspaces, codewords=codewords,
            depth=depth, block_size=128, nprobe=8,
            train_size=min(n, 16384), use_kernel=use_kernel,
        )
        t0 = time.time()
        ivf_state = ivf_s.build(jax.random.PRNGKey(3), X, R, cfg)
        flat_state = flat_s.attach(ivf_state.index, use_kernel=use_kernel)
        index = ivf_state.index
        st = flat_s.stats(flat_state)
        # residual distortion on a held sample — the strict quantization-
        # quality metric behind the recall frontier (recall can saturate)
        XRs = X[:4096] @ index.R
        res_s = XRs - index.coarse.centroids[index.coarse.assign(XRs)]
        sample_distortion = float(index.quantizer.distortion(res_s))
        out(f"# [{name}] built IVF index: N={n} L={lists} D={subspaces} "
            f"K={codewords} depth={depth} cap={st['capacity']} "
            f"code_bytes/item={st['code_bytes_per_row']} "
            f"({st['compression']:.0f}x compression) "
            f"residual_distortion={sample_distortion:.4f} "
            f"max_list_blocks={ivf_state.max_blocks} ({time.time()-t0:.1f}s)")

        # --- flat backend over the same codes the ivf backend probes
        flat_res = flat_s.search(flat_state, Q, k=10)
        flat_dt = _bench(lambda: flat_s.search(flat_state, Q, k=10).scores)
        flat_ids = np.asarray(flat_res.ids)
        flat_scan = st["capacity"]
        r_flat_exact = recall_at_k(flat_ids, exact_ids)
        out(f"flat_adc,{name},-,{flat_scan},1.0x,{queries/flat_dt:.0f},"
            f"1.000,{r_flat_exact:.3f}")
        swept.add("flat_adc")

        # --- int8 ADC LUT pack over the same index: the per-step LUT DMA
        # shrinks 4× and recall must stay within 0.01 of the f32 tables
        flat8_state = flat_s.attach(index, use_kernel=use_kernel,
                                    lut_dtype="int8")
        flat8_ids = np.asarray(flat_s.search(flat8_state, Q, k=10).ids)
        flat8_dt = _bench(lambda: flat_s.search(flat8_state, Q, k=10).scores)
        r_flat8 = recall_at_k(flat8_ids, exact_ids)
        out(f"flat_adc[int8],{name},-,{flat_scan},1.0x,"
            f"{queries/flat8_dt:.0f},-,{r_flat8:.3f}")
        checks[f"{name}_int8_recall_within_0.01"] = (
            r_flat8 >= r_flat_exact - 0.01)

        rows = []
        passed = False
        for nprobe in (1, 2, 4, 8, 16, 32, 64):
            if nprobe > lists:
                break
            res = ivf_s.search(ivf_state, Q, k=10, nprobe=nprobe)
            dt = _bench(lambda np_=nprobe: ivf_s.search(
                ivf_state, Q, k=10, nprobe=np_).scores)
            qps = queries / dt
            scan = float(np.mean(np.asarray(res.scanned)))
            reduction = flat_scan / max(scan, 1.0)
            ids_np = np.asarray(res.ids)
            r_flat = recall_at_k(ids_np, flat_ids)
            r_exact = recall_at_k(ids_np, exact_ids)
            rows.append(dict(nprobe=nprobe, scan=scan, reduction=reduction,
                             qps=qps, recall_flat=r_flat, recall_exact=r_exact))
            out(f"ivf,{name},{nprobe},{scan:.0f},{reduction:.1f}x,{qps:.0f},"
                f"{r_flat:.3f},{r_exact:.3f}")
            if r_flat >= 0.9 and reduction >= 5.0:
                passed = True
        swept.add("ivf")

        # --- rotation refresh through the protocol: the same RotationDelta
        # the trainer would emit, absorbed by Searcher.refresh
        def distortion_loss(Rm, index=index):
            return index.quantizer.distortion(X[:8192] @ Rm)

        G = jax.grad(distortion_loss)(index.R)
        learner = rotations.make("subspace_gcd", sub=index.quantizer.sub)
        _, delta = learner.update(learner.init_from(index.R), G, 2e-3,
                                  jax.random.PRNGKey(0))
        refreshed = ivf_s.refresh(ivf_state, delta)
        mismatch = float(maintain.refresh_mismatch(refreshed.index, X))
        post = ivf_s.search(refreshed, Q, k=10, nprobe=min(32, lists))
        post_recall = recall_at_k(np.asarray(post.ids), exact_ids)
        out(f"# [{name}] Searcher.refresh (subspace GCD delta): code mismatch "
            f"vs full rebuild = {mismatch*100:.2f}%, post-refresh "
            f"recall@10 vs exact = {post_recall:.3f}")

        results[name] = dict(rows=rows, flat_recall_exact=r_flat_exact,
                             int8_recall_exact=r_flat8,
                             compression=st["compression"],
                             refresh_mismatch=mismatch,
                             post_refresh_recall=post_recall,
                             residual_distortion=sample_distortion)
        full_probe_recall[name] = (r_flat_exact, sample_distortion)
        if depth == 1:
            checks["pq_scan_reduction_at_recall"] = passed

            # --- Engine: ragged batches, one compile per (bucket, k, nprobe)
            engine = search.Engine(ivf_s, ivf_state, k=10, nprobe=8,
                                   min_bucket=32)
            sizes = (31, 60, 17, 31, queries)
            for sz in sizes:
                engine.search(np.asarray(Q)[:sz])
            es = engine.stats()
            # expected bucket set through the Engine's own bucketing, so
            # the acceptance check cannot drift from the implementation
            buckets = {engine._bucket(sz) for sz in sizes}
            checks["engine_compile_cache"] = es["compiles"] <= len(buckets)
            results["engine"] = dict(
                compiles=es["compiles"], requests=es["requests"],
                lut_hit_rate=es["lut_hit_rate"],
                latency_ms_p50=es["latency_ms_p50"])
            out(f"# [engine] {es['requests']} ragged batches over buckets "
                f"{sorted(buckets)} -> {es['compiles']} compiles, LUT hit "
                f"rate {es['lut_hit_rate']:.2f}, p50 "
                f"{es['latency_ms_p50']:.1f} ms")

            # --- fused-refresh Engine: the live delta is absorbed on the
            # query side, so refresh costs zero recompiles and zero
            # LUT-cache invalidations (trace-counter verified), and the
            # post-refresh batch reuses every cached LUT row
            fstate = flat_s.attach(index, use_kernel=use_kernel,
                                   lut_dtype="int8", fused_refresh=True)
            feng = search.Engine(flat_s, fstate, k=10, min_bucket=32)
            feng.search(np.asarray(Q))
            fc0 = feng.stats()["compiles"]
            feng.refresh(delta)
            post_f = feng.search(np.asarray(Q))
            fs = feng.stats()
            fr = recall_at_k(np.asarray(post_f.ids), exact_ids)
            checks["fused_refresh_no_recompile"] = fs["compiles"] == fc0
            checks["fused_refresh_no_lut_invalidation"] = (
                fs["lut_invalidations"] == 0 and fs["lut_epoch"] == 0)
            results["fused_engine"] = dict(
                compiles=fs["compiles"],
                lut_invalidations=fs["lut_invalidations"],
                lut_hits=fs["lut_hits"], post_refresh_recall=fr)
            out(f"# [engine:fused int8] refresh -> recompiles "
                f"{fs['compiles'] - fc0}, lut_invalidations "
                f"{fs['lut_invalidations']}, lut_hits {fs['lut_hits']}, "
                f"post-refresh recall@10 vs exact = {fr:.3f}")

        else:
            # RQ end-to-end: built, searched, refreshed; refresh stays exact
            # (subspace matching) and recall survives the refresh.
            checks[f"{name}_end_to_end"] = (
                mismatch <= 0.01 and np.isfinite(post_recall)
                and post_recall > 0.0
            )

        if depth == depths[0]:
            # --- sharded twins on the local mesh (S = device_count; 1 in a
            # plain run — the --devices sweep below forces a real shard
            # count): same artifacts, so recall must match the replicated
            # backend row for row. First depth rather than depth 1, so a
            # --depths 2 run still sweeps (and ticks) every registry name.
            from repro.launch.mesh import make_data_mesh
            mesh = make_data_mesh()
            S = jax.device_count()
            sharded_ok = True
            ivf8_ids = np.asarray(
                ivf_s.search(ivf_state, Q, k=10, nprobe=8).ids)
            for sh_name, want_ids in (
                    ("exact_sharded", exact_ids),
                    ("flat_sharded", flat_ids),
                    ("ivf_sharded", ivf8_ids)):
                sh_s = search.make(sh_name, mesh=mesh)
                if sh_name == "exact_sharded":
                    sh_state = sh_s.build(
                        key, X, R, search.SearchConfig(tile_rows=8192))
                else:
                    sh_state = type(sh_s).attach(index, mesh=mesh, nprobe=8)
                kw = {"nprobe": 8} if sh_name == "ivf_sharded" else {}
                res = sh_s.search(sh_state, Q, k=10, **kw)
                dt = _bench(lambda s_=sh_s, st_=sh_state, kw_=kw: s_.search(
                    st_, Q, k=10, **kw_).scores)
                ids_np = np.asarray(res.ids)
                r_exact = recall_at_k(ids_np, exact_ids)
                sharded_ok &= recall_at_k(ids_np, want_ids) >= 0.999
                per_dev = float(np.mean(np.asarray(res.scanned))) / S
                out(f"{sh_name},{name},{'8' if kw else '-'},{per_dev:.0f}"
                    f"/dev×{S},-,{queries/dt:.0f},-,{r_exact:.3f}")
                swept.add(sh_name)
            checks["sharded_parity"] = sharded_ok

    if 1 in depths and len(full_probe_recall) > 1:
        pq_r, pq_d = full_probe_recall["pq"]
        best_rq = max(v[0] for k, v in full_probe_recall.items() if k != "pq")
        best_rq_d = min(v[1] for k, v in full_probe_recall.items()
                        if k != "pq")
        # more code bits per item must buy strictly lower residual
        # distortion (recall can saturate and tie on easy corpora — the
        # distortion metric cannot) without losing end-to-end recall
        checks["rq_beats_pq_quantization"] = (
            best_rq_d < pq_d and best_rq >= pq_r - 1e-6
        )
        out(f"# frontier: flat recall@10 vs exact — pq={pq_r:.3f}, "
            f"best rq={best_rq:.3f}; residual distortion — pq={pq_d:.4f}, "
            f"best rq={best_rq_d:.4f}")

    if devices > 1:
        cell = _run_sharded_cell(
            devices, n=n, dim=dim, queries=queries, lists=lists,
            subspaces=subspaces, codewords=codewords)
        results["sharded"] = cell
        out(f"# [sharded --devices {devices}] scan/query: "
            f"{cell['scan_single']:.0f} (1 dev) -> "
            f"{cell['scan_per_device']:.0f}/dev "
            f"({cell['reduction_per_device']:.1f}x per-device reduction), "
            f"recall@10 {cell['recall_single']:.3f} -> "
            f"{cell['recall_sharded']:.3f}, refresh recompiles "
            f"{cell['refresh_recompiles']}")
        # near-linear: per-device scan work within 2x of the ideal 1/S slice
        # (block-padding rounds short per-shard lists up to whole tiles)
        checks["sharded_scan_linear"] = (
            cell["reduction_per_device"] >= devices / 2.0)
        checks["sharded_recall_unchanged"] = (
            cell["recall_sharded"] >= cell["recall_single"] - 1e-6
            and cell["parity"])
        checks["sharded_refresh_no_recompile"] = (
            cell["refresh_recompiles"] == 0)

    checks["registry_swept"] = swept == set(search.names())
    out(f"# ACCEPTANCE: {checks} -> "
        f"{'PASS' if all(checks.values()) else 'FAIL'}")
    return results, checks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--lists", type=int, default=256)
    ap.add_argument("--subspaces", type=int, default=16)
    ap.add_argument("--codewords", type=int, default=256)
    ap.add_argument("--depths", default="1,2",
                    help="comma list of residual depths (1=PQ, 2=RQ-2, ...)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas path (TPU; interpret mode is too slow here)")
    ap.add_argument("--devices", type=int, default=1,
                    help="run the sharded sweep on N forced host devices "
                         "(subprocess)")
    ap.add_argument("--out", default=None,
                    help="BENCH_ivf_recall_qps.json destination dir "
                         "(default $REPRO_BENCH_DIR; unset → print only)")
    args = ap.parse_args()
    depths = tuple(int(d) for d in args.depths.split(","))
    res, checks = run(
        n=args.n, dim=args.dim, queries=args.queries, lists=args.lists,
        subspaces=args.subspaces, codewords=args.codewords, depths=depths,
        use_kernel=args.use_kernel, devices=args.devices)
    from repro import obs

    # --out > $REPRO_BENCH_DIR (no benchmarks.run import: this file also
    # runs script-style as `python benchmarks/ivf_recall_qps.py`)
    out_dir = args.out or os.environ.get("REPRO_BENCH_DIR")
    if out_dir:
        path = obs.write_bench(out_dir, "ivf_recall_qps",
                               sections={"ivf": res}, checks=checks,
                               config=vars(args))
        print(f"# BENCH written: {path}")


if __name__ == "__main__":
    main()
