"""Recall@10 vs QPS: IVF-PQ ``nprobe`` sweep against the flat ADC scan.

Builds a 100k synthetic corpus index (GCD-rotated residual PQ, repro.index)
and sweeps ``nprobe`` to trace the serving trade-off:

  * scan work   — CSR rows scored per query (the hardware-independent cost)
  * QPS         — measured wall-clock throughput of the jit'd search
  * recall@10   — (a) vs the flat ADC scan over the same quantized codes
                  (isolates the loss from probing, the thing nprobe controls)
                  (b) vs exact MIPS (end-to-end quality)

Acceptance line (ISSUE 1): at ≥0.9 recall@10-vs-flat, scan work must drop
≥5× vs the flat path.

Run:  PYTHONPATH=src python benchmarks/ivf_recall_qps.py [--n 100000]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import givens, pq
from repro.data import synthetic
from repro.index import ivf, maintain, search
from repro.metrics import recall_at_k


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--lists", type=int, default=256)
    ap.add_argument("--subspaces", type=int, default=16)
    ap.add_argument("--codewords", type=int, default=256)
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas path (TPU; interpret mode is too slow here)")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    X = synthetic.sift_like(key, args.n, args.dim)
    Q = synthetic.sift_like(jax.random.PRNGKey(1), args.queries, args.dim)
    R = givens.random_rotation(jax.random.PRNGKey(2), args.dim)

    cfg = ivf.IVFPQConfig(
        num_lists=args.lists,
        pq=pq.PQConfig(args.subspaces, args.codewords),
        block_size=128,
    )
    t0 = time.time()
    index = ivf.build(jax.random.PRNGKey(3), X, R, cfg, train_size=16384)
    print(f"# built IVF-PQ index: N={args.n} L={args.lists} "
          f"D={args.subspaces} K={args.codewords} cap={index.capacity} "
          f"max_list_blocks={index.max_list_blocks()} "
          f"({time.time()-t0:.1f}s)")

    exact = np.asarray(jnp.argsort(-(Q @ X.T), axis=1)[:, :10])

    # --- flat baseline over the same quantized representation
    @jax.jit
    def flat(qb):
        scores, ids = search.flat_adc_scores(index, qb)
        s, pos = jax.lax.top_k(scores, 10)
        return s, ids[pos]

    _, flat_ids = flat(Q)
    jax.block_until_ready(flat_ids)
    t0 = time.time()
    reps = 3
    for _ in range(reps):
        jax.block_until_ready(flat(Q)[0])
    flat_dt = (time.time() - t0) / reps
    flat_qps = args.queries / flat_dt
    flat_scan = index.capacity
    flat_ids = np.asarray(flat_ids)
    print(f"# flat ADC: scan={flat_scan} rows/query "
          f"qps={flat_qps:.0f} recall@10 vs exact="
          f"{recall_at_k(flat_ids, exact):.3f}")
    print("nprobe,scan_rows,scan_reduction,qps,recall10_vs_flat,recall10_vs_exact")

    passed = False
    max_blocks = index.max_list_blocks()  # hoisted: no host sync in the loop
    for nprobe in (1, 2, 4, 8, 16, 32, 64):
        if nprobe > args.lists:
            break
        res = search.search_fixed(index, Q, nprobe=nprobe, k=10,
                                  max_blocks=max_blocks,
                                  use_kernel=args.use_kernel)
        jax.block_until_ready(res.scores)
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(
                search.search_fixed(index, Q, nprobe=nprobe, k=10,
                                    max_blocks=max_blocks,
                                    use_kernel=args.use_kernel).scores)
        dt = (time.time() - t0) / reps
        qps = args.queries / dt
        scan = float(jnp.mean(res.scanned))
        reduction = flat_scan / max(scan, 1.0)
        ids_np = np.asarray(res.ids)
        r_flat = recall_at_k(ids_np, flat_ids)
        r_exact = recall_at_k(ids_np, exact)
        print(f"{nprobe},{scan:.0f},{reduction:.1f}x,{qps:.0f},"
              f"{r_flat:.3f},{r_exact:.3f}")
        if r_flat >= 0.9 and reduction >= 5.0:
            passed = True

    # --- rotation refresh: the index stays servable across a GCD step
    def distortion_loss(Rm):
        return pq.distortion(X[:8192] @ Rm, index.codebooks)

    G = jax.grad(distortion_loss)(index.R)
    refreshed, _ = maintain.subspace_gcd_step(index, G, 2e-3)
    mismatch = float(maintain.refresh_mismatch(refreshed, X))
    print(f"# refresh_rotation (subspace GCD step): code mismatch vs full "
          f"rebuild = {mismatch*100:.2f}% (exact up to fp-rounding ties)")

    print(f"# ACCEPTANCE (≥5x scan reduction at ≥0.9 recall@10 vs flat): "
          f"{'PASS' if passed else 'FAIL'}")


if __name__ == "__main__":
    main()
