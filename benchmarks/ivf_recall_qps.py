"""Recall@10 vs QPS: IVF ``nprobe`` sweep, PQ vs depth-2 residual RQ.

Builds synthetic-corpus indexes (GCD-rotated residual quantizer,
repro.index) for each residual depth and sweeps ``nprobe`` to trace the
serving trade-offs the ``repro.quant`` abstraction buys:

  * scan work   — CSR rows scored per query (the hardware-independent cost)
  * QPS         — measured wall-clock throughput of the jit'd search
  * recall@10   — (a) vs the flat ADC scan over the same quantized codes
                  (isolates the loss from probing, the thing nprobe controls)
                  (b) vs exact MIPS (end-to-end quality)
  * compression — corpus f32 bytes / code payload bytes (RQ-M spends M×
                  the code bytes of PQ for strictly lower distortion — the
                  recall/compression frontier)

Acceptance (ISSUE 1, carried forward): at ≥0.9 recall@10-vs-flat, PQ scan
work must drop ≥5× vs the flat path. ISSUE 2 adds: RQ depth-2 must run
end-to-end through build, search, and ``refresh_rotation``, and beat PQ's
recall@10-vs-exact at full probe (more code bits → better quantization).

Run:  PYTHONPATH=src python benchmarks/ivf_recall_qps.py [--n 100000]
      PYTHONPATH=src python -m benchmarks.run --only ivf [--fast]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.core import givens
from repro.data import synthetic
from repro.index import ivf, maintain, search
from repro.metrics import recall_at_k


def _bench(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def run(n: int = 100_000, dim: int = 64, queries: int = 256, lists: int = 256,
        subspaces: int = 16, codewords: int = 256, depths=(1, 2),
        use_kernel: bool = False, verbose: bool = True):
    """Sweep residual depths; returns (results dict, claim-check dict)."""
    out = print if verbose else (lambda *a, **k: None)
    key = jax.random.PRNGKey(0)
    X = synthetic.sift_like(key, n, dim)
    Q = synthetic.sift_like(jax.random.PRNGKey(1), queries, dim)
    R = givens.random_rotation(jax.random.PRNGKey(2), dim)
    exact = np.asarray(jnp.argsort(-(Q @ X.T), axis=1)[:, :10])

    results: dict = {}
    checks: dict = {}
    full_probe_recall: dict = {}

    for depth in depths:
        name = "pq" if depth == 1 else f"rq{depth}"
        cfg = ivf.IVFPQConfig(
            num_lists=lists,
            pq=quant.PQConfig(subspaces, codewords),
            block_size=128,
            depth=depth,
        )
        t0 = time.time()
        index = ivf.build(jax.random.PRNGKey(3), X, R, cfg,
                          train_size=min(n, 16384))
        code_bytes = index.codes.shape[1] * index.codes.dtype.itemsize
        compression = dim * 4 / code_bytes
        # residual distortion on a held sample — the strict quantization-
        # quality metric behind the recall frontier (recall can saturate)
        XRs = X[:4096] @ index.R
        res_s = XRs - index.coarse.centroids[index.coarse.assign(XRs)]
        sample_distortion = float(index.quantizer.distortion(res_s))
        out(f"# [{name}] built IVF index: N={n} L={lists} D={subspaces} "
            f"K={codewords} depth={depth} cap={index.capacity} "
            f"code_bytes/item={code_bytes} ({compression:.0f}x compression) "
            f"residual_distortion={sample_distortion:.4f} "
            f"max_list_blocks={index.max_list_blocks()} "
            f"({time.time()-t0:.1f}s)")

        # --- flat baseline over the same quantized representation
        @jax.jit
        def flat(qb, index=index):
            scores, ids = search.flat_adc_scores(index, qb)
            s, pos = jax.lax.top_k(scores, 10)
            return s, ids[pos]

        flat_dt = _bench(lambda: flat(Q)[0])
        flat_ids = np.asarray(flat(Q)[1])
        flat_scan = index.capacity
        r_flat_exact = recall_at_k(flat_ids, exact)
        out(f"# [{name}] flat ADC: scan={flat_scan} rows/query "
            f"qps={queries/flat_dt:.0f} recall@10 vs exact={r_flat_exact:.3f}")
        out("scheme,nprobe,scan_rows,scan_reduction,qps,"
            "recall10_vs_flat,recall10_vs_exact")

        rows = []
        passed = False
        max_blocks = index.max_list_blocks()  # hoisted: no host sync in loop
        for nprobe in (1, 2, 4, 8, 16, 32, 64):
            if nprobe > lists:
                break
            res = search.search_fixed(index, Q, nprobe=nprobe, k=10,
                                      max_blocks=max_blocks,
                                      use_kernel=use_kernel)
            dt = _bench(lambda np_=nprobe: search.search_fixed(
                index, Q, nprobe=np_, k=10, max_blocks=max_blocks,
                use_kernel=use_kernel).scores)
            qps = queries / dt
            scan = float(jnp.mean(res.scanned))
            reduction = flat_scan / max(scan, 1.0)
            ids_np = np.asarray(res.ids)
            r_flat = recall_at_k(ids_np, flat_ids)
            r_exact = recall_at_k(ids_np, exact)
            rows.append(dict(nprobe=nprobe, scan=scan, reduction=reduction,
                             qps=qps, recall_flat=r_flat, recall_exact=r_exact))
            out(f"{name},{nprobe},{scan:.0f},{reduction:.1f}x,{qps:.0f},"
                f"{r_flat:.3f},{r_exact:.3f}")
            if r_flat >= 0.9 and reduction >= 5.0:
                passed = True

        # --- rotation refresh: the index stays servable across a GCD step
        def distortion_loss(Rm, index=index):
            return index.quantizer.distortion(X[:8192] @ Rm)

        G = jax.grad(distortion_loss)(index.R)
        refreshed, _ = maintain.subspace_gcd_step(index, G, 2e-3)
        mismatch = float(maintain.refresh_mismatch(refreshed, X))
        post = search.search(refreshed, Q, nprobe=min(32, lists), k=10,
                             use_kernel=use_kernel)
        post_recall = recall_at_k(np.asarray(post.ids), exact)
        out(f"# [{name}] refresh_rotation (subspace GCD step): code mismatch "
            f"vs full rebuild = {mismatch*100:.2f}%, post-refresh "
            f"recall@10 vs exact = {post_recall:.3f}")

        results[name] = dict(rows=rows, flat_recall_exact=r_flat_exact,
                             compression=compression, refresh_mismatch=mismatch,
                             post_refresh_recall=post_recall,
                             residual_distortion=sample_distortion)
        full_probe_recall[name] = (r_flat_exact, sample_distortion)
        if depth == 1:
            checks["pq_scan_reduction_at_recall"] = passed
        else:
            # RQ end-to-end: built, searched, refreshed; refresh stays exact
            # (subspace matching) and recall survives the refresh.
            checks[f"{name}_end_to_end"] = (
                mismatch <= 0.01 and np.isfinite(post_recall)
                and post_recall > 0.0
            )

    if 1 in depths and len(full_probe_recall) > 1:
        pq_r, pq_d = full_probe_recall["pq"]
        best_rq = max(v[0] for k, v in full_probe_recall.items() if k != "pq")
        best_rq_d = min(v[1] for k, v in full_probe_recall.items()
                        if k != "pq")
        # more code bits per item must buy strictly lower residual
        # distortion (recall can saturate and tie on easy corpora — the
        # distortion metric cannot) without losing end-to-end recall
        checks["rq_beats_pq_quantization"] = (
            best_rq_d < pq_d and best_rq >= pq_r - 1e-6
        )
        out(f"# frontier: flat recall@10 vs exact — pq={pq_r:.3f}, "
            f"best rq={best_rq:.3f}; residual distortion — pq={pq_d:.4f}, "
            f"best rq={best_rq_d:.4f}")

    out(f"# ACCEPTANCE: {checks} -> "
        f"{'PASS' if all(checks.values()) else 'FAIL'}")
    return results, checks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--lists", type=int, default=256)
    ap.add_argument("--subspaces", type=int, default=16)
    ap.add_argument("--codewords", type=int, default=256)
    ap.add_argument("--depths", default="1,2",
                    help="comma list of residual depths (1=PQ, 2=RQ-2, ...)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas path (TPU; interpret mode is too slow here)")
    args = ap.parse_args()
    depths = tuple(int(d) for d in args.depths.split(","))
    run(n=args.n, dim=args.dim, queries=args.queries, lists=args.lists,
        subspaces=args.subspaces, codewords=args.codewords, depths=depths,
        use_kernel=args.use_kernel)


if __name__ == "__main__":
    main()
