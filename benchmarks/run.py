"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure (+ kernel microbench + roofline
aggregation). Prints ``name,us_per_call,derived`` CSV. Use
``--only fig2a,fig4`` to run a subset, ``--fast`` for the CI-sized pass.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig2a,fig2bc,table1,fig4,ivf,kernels,"
                         "roofline")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--devices", type=int, default=1,
                    help="ivf section: run the sharded sweep on N forced "
                         "host devices (subprocess)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []

    if want("fig2a"):
        from benchmarks import fig2a_convergence
        _res, checks = fig2a_convergence.run(
            num=2048 if args.fast else 4096,
            iters=15 if args.fast else 25)
        failures += [f"fig2a/{k}" for k, v in checks.items() if not v]

    if want("fig2bc"):
        from benchmarks import fig2bc_stability
        _out, stable = fig2bc_stability.run(
            num=2048 if args.fast else 4096,
            runs=3 if args.fast else 5,
            iters=12 if args.fast else 20)
        if not stable:
            failures.append("fig2bc/stability")

    if want("table1"):
        from benchmarks import fig3_table1_e2e
        _res, checks = fig3_table1_e2e.run(
            steps=60 if args.fast else 250,
            warmup=30 if args.fast else 40)
        failures += [f"table1/{k}" for k, v in checks.items() if not v]

    if want("fig4"):
        from benchmarks import fig4_runtime
        _out, checks = fig4_runtime.run(
            dims=(64, 128, 256) if args.fast else (64, 128, 256, 512))
        failures += [f"fig4/{k}" for k, v in checks.items() if not v]

    if want("ivf"):
        # searcher-registry sweep: exact vs flat_adc vs ivf on one harness
        from benchmarks import ivf_recall_qps
        _res, checks = ivf_recall_qps.run(
            n=20_000 if args.fast else 100_000,
            queries=64 if args.fast else 256,
            lists=64 if args.fast else 256,
            depths=(1, 2),
            devices=args.devices)
        failures += [f"ivf/{k}" for k, v in checks.items() if not v]

    if want("kernels"):
        from benchmarks import kernels_micro
        results = kernels_micro.run()
        failures += [f"kernels/{k}" for k, v in results.items() if not v]

    if want("roofline"):
        from benchmarks import roofline_table
        roofline_table.run()

    print(f"# total {time.time()-t0:.1f}s; claim-check failures: "
          f"{failures if failures else 'none'}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
