"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure (+ kernel microbench + roofline
aggregation). Prints ``name,us_per_call,derived`` CSV while running, and
emits one merged ``BENCH_<fast|full>.json`` run record through the
``repro.obs`` trajectory writer — every section's results and claim checks
in one schema-valid file, appended to the destination trajectory so perf
history is pinned rather than scrolled away.

Destination resolution: ``--out DIR`` > ``$REPRO_BENCH_DIR`` > (for
``--fast`` only) the repo's ``benchmarks/`` directory — the committed
trajectory a fast run extends by default. A full run without an explicit
destination prints only. Use ``--only fig2a,fig4`` for a subset.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

_BENCH_DIR = os.path.dirname(os.path.abspath(__file__))


def resolve_bench_dir(cli_out: str | None,
                      fast_default: bool = False) -> str | None:
    """--out > $REPRO_BENCH_DIR > (--fast) the tracked benchmarks/ dir."""
    if cli_out:
        return cli_out
    env = os.environ.get("REPRO_BENCH_DIR")
    if env:
        return env
    return _BENCH_DIR if fast_default else None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig2a,fig2bc,table1,fig4,ivf,churn,"
                         "train_e2e,"
                         "serve,kernels,roofline")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--devices", type=int, default=1,
                    help="ivf/churn sections: run the sharded cells on N "
                         "forced host devices (subprocess)")
    ap.add_argument("--out", default=None,
                    help="BENCH_*.json destination dir (default "
                         "$REPRO_BENCH_DIR; --fast falls back to the "
                         "tracked benchmarks/ trajectory)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    sections: dict = {}
    checks_all: dict = {}

    def book(name: str, results, checks: dict | None = None) -> None:
        sections[name] = results
        for k, v in (checks or {}).items():
            key = f"{name}/{k}"
            checks_all[key] = bool(v)
            if not v:
                failures.append(key)

    if want("train_e2e"):
        # overlapped end-to-end training: async prefetch + live refresh +
        # background compaction with staleness re-encode — step overhead,
        # hidden-pause p99, and recall-vs-rebuild pinned. Runs FIRST: the
        # p99 pins compare an off-thread pack against an inline one, and a
        # heap pre-warmed by other sections skews the two arms differently
        # (standalone conditions are the calibrated ones).
        from benchmarks import train_e2e
        if args.fast:
            res, checks = train_e2e.run(
                n=32000, dim=32, queries=64, lists=32, subspaces=8,
                codewords=32, steps=54, batch=8192, nprobe=8,
                refresh_every=6, compact_every=3, reencode_rows=2048,
                staging_rows=512, churn_batch=32, churn_every=3,
                warmup=12, probe_every=6)
        else:
            res, checks = train_e2e.run()
        book("train_e2e", res, checks)

    if want("fig2a"):
        from benchmarks import fig2a_convergence
        res, checks = fig2a_convergence.run(
            num=2048 if args.fast else 4096,
            iters=15 if args.fast else 25)
        book("fig2a", res, checks)

    if want("fig2bc"):
        from benchmarks import fig2bc_stability
        out, stable = fig2bc_stability.run(
            num=2048 if args.fast else 4096,
            runs=3 if args.fast else 5,
            iters=12 if args.fast else 20)
        book("fig2bc", out, {"stability": stable})

    if want("table1"):
        from benchmarks import fig3_table1_e2e
        res, checks = fig3_table1_e2e.run(
            steps=60 if args.fast else 250,
            warmup=30 if args.fast else 40)
        book("table1", res, checks)

    if want("fig4"):
        from benchmarks import fig4_runtime
        out, checks = fig4_runtime.run(
            dims=(64, 128, 256) if args.fast else (64, 128, 256, 512))
        book("fig4", out, checks)

    if want("ivf"):
        # searcher-registry sweep: exact vs flat_adc vs ivf on one harness
        from benchmarks import ivf_recall_qps
        res, checks = ivf_recall_qps.run(
            n=20_000 if args.fast else 100_000,
            queries=64 if args.fast else 256,
            lists=64 if args.fast else 256,
            depths=(1, 2),
            devices=args.devices)
        book("ivf", res, checks)

    if want("churn"):
        # live mutations under query load: staged adds, in-kernel
        # tombstones, compaction — zero recompiles, recall pinned
        from benchmarks import churn as churn_bench
        if args.fast:
            res, checks = churn_bench.run(
                n=8000, dim=32, queries=64, lists=32, subspaces=8,
                codewords=32, steps=6, batch=64, nprobe=8,
                staging_rows=512, devices=args.devices)
        else:
            res, checks = churn_bench.run(devices=args.devices)
        book("churn", res, checks)

    if want("serve"):
        # multi-tenant serving under Poisson load: continuous batching +
        # SLO-adaptive nprobe vs fixed baselines, isolation pinned
        from benchmarks import serve_load
        if args.fast:
            res, checks = serve_load.run(
                n=8000, dim=32, lists=128, subspaces=16, codewords=64,
                ladder=(2, 4, 16), requests=600, max_admit=8,
                refresh_every=150)
        else:
            res, checks = serve_load.run()
        book("serve", res, checks)

    if want("kernels"):
        from benchmarks import kernels_micro
        results = kernels_micro.run()
        book("kernels", results,
             {k: v["ok"] for k, v in results.items()})

    if want("roofline"):
        from benchmarks import roofline_table
        res = roofline_table.run()
        book("roofline", res)

    elapsed = time.time() - t0
    print(f"# total {elapsed:.1f}s; claim-check failures: "
          f"{failures if failures else 'none'}")

    out_dir = resolve_bench_dir(args.out, fast_default=args.fast)
    if out_dir and sections:
        from repro import obs

        name = "fast" if args.fast else "full"
        path = obs.write_bench(
            out_dir, name, sections=sections, checks=checks_all,
            config=dict(only=sorted(only) if only else None,
                        fast=args.fast, devices=args.devices,
                        elapsed_s=elapsed))
        errs = obs.validate_bench(path)
        print(f"# BENCH written: {path} "
              f"({'schema-valid' if not errs else f'INVALID: {errs}'})")
        if errs:
            sys.exit(1)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
