"""Fig 3 + Table 1 reproduction: end-to-end trainable embedding index.

Paper §3.2 protocol, CPU-sized: a two-tower retrieval model (cosine scoring,
hinge margin 0.1) on a synthetic click log with known ground truth.
Warm-up steps without the index layer → OPQ warm start of (R, codebooks) →
joint training where R is updated per rotation learner:

  frozen | cayley_sgd | gcd_random | gcd_greedy | gcd_steepest

Every row goes through the same ``training.optimizer`` path — the learner is
just ``OptimizerConfig.rotation`` (the ``repro.rotations`` registry), so the
Cayley row genuinely *trains* R through the Cayley retraction rather than
aliasing to a frozen rotation (the check ``cayley_r_trains`` asserts its R
departs from the OPQ warm start).

Reported per method: final quantization distortion (Fig 3) and p@k / r@k of
ADC retrieval against latent-similarity ground truth (Table 1).
Paper claims checked: every trainable-R method beats the frozen baseline on
distortion; GCD-S ≥ GCD-G ≥ GCD-R ordering holds (within tolerance).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro import quant, rotations
from repro.configs import paper_twotower
from repro.core import index_layer as il
from repro.data import synthetic
from repro.models import recsys
from repro.training import optimizer as opt_lib
from repro.training import train_state as ts

# the paper's Table 1 rows, as registry specs (swept from the registry so a
# new learner is one string away from an e2e row)
METHODS = [m for m in rotations.names()
           if m in ("frozen", "cayley_sgd", "gcd_random", "gcd_greedy",
                    "gcd_steepest")]
# manifold lr per learner: the Cayley retraction's pull-back rescales the
# gradient (≈2× the GCD directional derivatives), so it takes a smaller step
ROT_LRS = {"cayley_sgd": 1e-3}


def _retrieval_metrics(params, cfg, log, k=100, num_queries=64):
    hist, truth = log.eval_queries(7, num_queries, cfg.hist_len, k_truth=k)
    # encode the whole corpus through the item tower + PQ index
    ids = jnp.arange(cfg.item_vocab)
    vecs, _ = recsys.item_tower(params, ids, cfg, apply_index=False)
    vecs = vecs / jnp.maximum(jnp.linalg.norm(vecs, axis=-1, keepdims=True), 1e-6)
    codes = il.encode(params["index"], vecs)
    scores = recsys.twotower_retrieve_adc(params, hist, codes, cfg)
    top = np.asarray(jnp.argsort(-scores, axis=-1)[:, :k])
    hits = np.array([
        len(set(top[i].tolist()) & set(truth[i].tolist())) for i in range(len(top))
    ])
    return float(hits.mean() / k), float(hits.mean() / truth.shape[1])


def run(steps=250, warmup=40, batch=64, seed=0, verbose=True,
        item_vocab=1024):
    cfg = paper_twotower.make_smoke()._replace(item_vocab=item_vocab)
    log = synthetic.ClickLog(seed, cfg.item_vocab, dim=32)
    results = {}
    for method in METHODS:
        key = jax.random.PRNGKey(seed)
        params = recsys.twotower_init(key, cfg)
        ocfg = opt_lib.OptimizerConfig(
            lr=3e-3, total_steps=steps, warmup_steps=10,
            rotation=rotations.RotationConfig.from_spec(
                method, lr=ROT_LRS.get(method, 3e-3)),
        )

        # Phase 1: warm-up without the index layer (paper: 10k steps scaled down)
        def warm_loss(p, h, pos):
            return recsys.twotower_loss(p, h, pos, cfg, use_index=False)

        state = ts.init_state(jax.random.fold_in(key, 1), params, ocfg)
        warm_step = jax.jit(ts.make_train_step(warm_loss, ocfg))
        for i in range(warmup):
            h, pos = log.batch(1000 + i, batch, cfg.hist_len)
            state, _ = warm_step(state, h, pos)

        # Phase 2: OPQ warm start of (R, codebooks) on a sample of item vecs
        sample_ids = jnp.arange(min(1024, cfg.item_vocab))
        v, _ = recsys.item_tower(state.params, sample_ids, cfg, apply_index=False)
        idx_params = il.warm_start(jax.random.fold_in(key, 2), v, cfg.index,
                                   opq_iters=30)
        R_warm = np.asarray(idx_params.R)
        params = dict(state.params)
        params["index"] = idx_params
        state = state._replace(params=params,
                               opt_state=opt_lib.init(params, ocfg))

        # Phase 3: joint training; R updated by the configured learner
        def joint_loss(p, h, pos):
            return recsys.twotower_loss(p, h, pos, cfg, use_index=True)

        step = jax.jit(ts.make_train_step(joint_loss, ocfg))
        for i in range(steps):
            h, pos = log.batch(2000 + i, batch, cfg.hist_len)
            state, m = step(state, h, pos)
        final_params = state.params

        # final distortion on fresh item-tower outputs
        v, _ = recsys.item_tower(final_params, sample_ids, cfg, apply_index=False)
        phi = quant.PQ(final_params["index"].codebooks)
        dist = float(phi.distortion(v @ final_params["index"].R))
        p_at, r_at = _retrieval_metrics(final_params, cfg, log, k=50)
        dR = float(np.linalg.norm(
            np.asarray(final_params["index"].R) - R_warm))
        results[method] = {"distortion": dist, "p@50": p_at, "r@50": r_at,
                           "dR_from_warmstart": dR}
        if verbose:
            emit(f"table1/{method}", 0.0,
                 f"distortion={dist:.4f};p@50={p_at:.4f};r@50={r_at:.4f};"
                 f"dR={dR:.4f}")

    checks = {
        "trainable_beats_frozen": min(
            results[m]["distortion"]
            for m in ("gcd_random", "gcd_greedy", "gcd_steepest"))
        < results["frozen"]["distortion"],
        "greedy_le_random": results["gcd_greedy"]["distortion"]
        <= results["gcd_random"]["distortion"] * 1.05,
        "steepest_le_greedy": results["gcd_steepest"]["distortion"]
        <= results["gcd_greedy"]["distortion"] * 1.05,
        # the old harness silently substituted a frozen R for the Cayley row;
        # assert the trained-Cayley R actually departs from the warm start
        # (and that the frozen control does not). Threshold sits well below
        # the --fast-size movement (~7e-4 at 60 steps) and 7 orders above
        # frozen's exact 0.
        "cayley_r_trains": results["cayley_sgd"]["dR_from_warmstart"] > 1e-4,
        "frozen_r_stays": results["frozen"]["dR_from_warmstart"] < 1e-6,
    }
    if verbose:
        for k, v in checks.items():
            emit(f"table1/check/{k}", 0.0, str(v))
    return results, checks


if __name__ == "__main__":
    run()
