"""Pallas-kernel micro-benchmarks.

On this CPU container the kernels execute in interpret mode (Python), so
wall-clock numbers measure the XLA-oracle path and only CHECK the kernels'
numerics at benchmark shapes; the kernels' perf story on TPU is carried by
the §Roofline VMEM/BlockSpec analysis instead. Emits allclose status per
kernel at a production-ish shape.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro import rotations
from repro.kernels import ops, ref


def run(verbose=True):
    """Returns ``{kernel: {"ok": bool, "us_per_call": float}}`` — the
    numerics check plus the measured time, so the BENCH trajectory pins
    both (a kernel that got fast by going wrong fails the check)."""
    key = jax.random.PRNGKey(0)
    results = {}

    def record(name, ok, us, detail):
        results[name] = {"ok": bool(ok), "us_per_call": float(us)}
        if verbose:
            emit(f"kernels/{name}", us, detail)

    # givens_rotate @ (m=8192, n=512)
    m, n = 8192, 512
    X = jax.random.normal(key, (m, n))
    perm = np.random.RandomState(0).permutation(n)
    pi, pj = jnp.asarray(perm[: n // 2]), jnp.asarray(perm[n // 2:])
    theta = jax.random.normal(jax.random.fold_in(key, 1), (n // 2,))
    want = rotations.apply_pair_rotations(X, pi, pj, theta)
    got = ops.apply_pair_rotations(X, pi, pj, theta)
    ok = np.allclose(got, want, atol=1e-4)
    us = time_call(jax.jit(
        lambda x, a, b, t: ops.apply_pair_rotations(x, a, b, t, use_kernel=False)),
        X, pi, pj, theta)
    record("givens_rotate", ok, us, f"allclose={ok}")

    # gcd_score @ n=512
    G = jax.random.normal(key, (512, 512))
    R = jax.random.normal(jax.random.fold_in(key, 2), (512, 512))
    ok = np.allclose(ops.gcd_score(G, R), ref.gcd_score_ref(G, R), atol=1e-2)
    us = time_call(jax.jit(lambda g, r: ref.gcd_score_ref(g, r)), G, R)
    record("gcd_score", ok, us, f"allclose={ok}")

    # pq_assign @ (m=16384, n=512, D=64, K=256)
    Xq = jax.random.normal(key, (16384, 512))
    cb = jax.random.normal(jax.random.fold_in(key, 3), (64, 256, 8))
    ok = bool(jnp.all(ops.pq_assign(Xq, cb) == ref.pq_assign_ref(Xq, cb)))
    us = time_call(jax.jit(lambda x, c: ref.pq_assign_ref(x, c)), Xq, cb)
    record("pq_assign", ok, us, f"match={ok}")

    # adc_lookup @ (b=8, D=64, K=256, N=65536)
    lut = jax.random.normal(key, (8, 64, 256))
    codes = jax.random.randint(jax.random.fold_in(key, 4), (65536, 64), 0, 256)
    ok = np.allclose(ops.adc_lookup(lut, codes),
                     ref.adc_lookup_ref(lut, codes), atol=1e-3)
    us = time_call(jax.jit(lambda l, c: ref.adc_lookup_ref(l, c)), lut, codes)
    record("adc_lookup", ok, us, f"allclose={ok}")

    # embedding_bag @ (V=100k, dim=64, L=16384)
    table = jax.random.normal(key, (100_000, 64))
    idx = jax.random.randint(jax.random.fold_in(key, 5), (16384,), 0, 100_000)
    bags = jnp.sort(jax.random.randint(jax.random.fold_in(key, 6), (16384,), 0, 2048))
    got = ops.embedding_bag(table, idx, bags, 2048)
    want = ref.embedding_bag_ref(table, idx, bags, 2048)
    ok = np.allclose(got, want, atol=1e-3)
    us = time_call(jax.jit(
        lambda t, i, b: ref.embedding_bag_ref(t, i, b, 2048)), table, idx, bags)
    record("embedding_bag", ok, us, f"allclose={ok}")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="BENCH_kernels.json destination dir "
                         "(default $REPRO_BENCH_DIR; unset → print only)")
    args = ap.parse_args()
    results = run()
    from repro import obs
    from benchmarks.run import resolve_bench_dir

    out_dir = resolve_bench_dir(args.out)
    if out_dir:
        path = obs.write_bench(
            out_dir, "kernels", sections={"kernels": results},
            checks={f"kernels/{k}": v["ok"] for k, v in results.items()})
        print(f"# BENCH written: {path}")


if __name__ == "__main__":
    main()
