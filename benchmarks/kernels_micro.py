"""Pallas-kernel micro-benchmarks.

On this CPU container the kernels execute in interpret mode (Python), so
wall-clock numbers measure the XLA-oracle path and only CHECK the kernels'
numerics at benchmark shapes; the kernels' perf story on TPU is carried by
the §Roofline VMEM/BlockSpec analysis instead. Emits allclose status per
kernel at a production-ish shape.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro import rotations
from repro.kernels import ops, ref
from repro.roofline import analysis


def _topk_agree(a_ids, b_ids):
    """Mean per-row overlap of two (b, k) id sets."""
    a, b = np.asarray(a_ids), np.asarray(b_ids)
    k = a.shape[1]
    return float(np.mean([len(set(a[i]) & set(b[i])) / k
                          for i in range(a.shape[0])]))


def run(verbose=True, lut_dtype="int8"):
    """Returns ``{kernel: {"ok": bool, "us_per_call": float, ...}}`` — the
    numerics check plus the measured time, so the BENCH trajectory pins
    both (a kernel that got fast by going wrong fails the check). The PR 7
    sections additionally book the roofline-model bytes/prediction next to
    the measured time (``predicted_us`` is the TPU bound; on this CPU
    container the measured number is the XLA-oracle path, so the pair is
    recorded as data, not compared as a check).

    ``lut_dtype`` selects the quantized-LUT pack ("int8" | "uint8") the
    quantized sections exercise; the f32 sections always run.
    """
    key = jax.random.PRNGKey(0)
    results = {}

    def record(name, ok, us, detail, **extra):
        results[name] = {"ok": bool(ok), "us_per_call": float(us), **extra}
        if verbose:
            emit(f"kernels/{name}", us, detail)

    # givens_rotate @ (m=8192, n=512)
    m, n = 8192, 512
    X = jax.random.normal(key, (m, n))
    perm = np.random.RandomState(0).permutation(n)
    pi, pj = jnp.asarray(perm[: n // 2]), jnp.asarray(perm[n // 2:])
    theta = jax.random.normal(jax.random.fold_in(key, 1), (n // 2,))
    want = rotations.apply_pair_rotations(X, pi, pj, theta)
    got = ops.apply_pair_rotations(X, pi, pj, theta)
    ok = np.allclose(got, want, atol=1e-4)
    us = time_call(jax.jit(
        lambda x, a, b, t: ops.apply_pair_rotations(x, a, b, t, use_kernel=False)),
        X, pi, pj, theta)
    record("givens_rotate", ok, us, f"allclose={ok}")

    # gcd_score @ n=512 — kernel parity + kernel timing, with the jnp ref
    # timed as its own row (the old code checked the kernel but timed the
    # ref, so the trajectory pinned the wrong number under the kernel name)
    G = jax.random.normal(key, (512, 512))
    R = jax.random.normal(jax.random.fold_in(key, 2), (512, 512))
    ok = np.allclose(ops.gcd_score(G, R), ref.gcd_score_ref(G, R), atol=1e-2)
    us = time_call(jax.jit(lambda g, r: ops.gcd_score(g, r)), G, R)
    record("gcd_score", ok, us, f"allclose={ok}")
    us = time_call(jax.jit(lambda g, r: ref.gcd_score_ref(g, r)), G, R)
    record("gcd_score_ref", True, us, "jnp reference")

    # pq_assign @ (m=16384, n=512, D=64, K=256)
    Xq = jax.random.normal(key, (16384, 512))
    cb = jax.random.normal(jax.random.fold_in(key, 3), (64, 256, 8))
    ok = bool(jnp.all(ops.pq_assign(Xq, cb) == ref.pq_assign_ref(Xq, cb)))
    us = time_call(jax.jit(lambda x, c: ref.pq_assign_ref(x, c)), Xq, cb)
    record("pq_assign", ok, us, f"match={ok}")

    # adc_lookup @ (b=8, D=64, K=256, N=65536)
    lut = jax.random.normal(key, (8, 64, 256))
    codes = jax.random.randint(jax.random.fold_in(key, 4), (65536, 64), 0, 256)
    ok = np.allclose(ops.adc_lookup(lut, codes),
                     ref.adc_lookup_ref(lut, codes), atol=1e-3)
    us = time_call(jax.jit(lambda l, c: ref.adc_lookup_ref(l, c)), lut, codes)
    record("adc_lookup", ok, us, f"allclose={ok}")

    # embedding_bag @ (V=100k, dim=64, L=16384)
    table = jax.random.normal(key, (100_000, 64))
    idx = jax.random.randint(jax.random.fold_in(key, 5), (16384,), 0, 100_000)
    bags = jnp.sort(jax.random.randint(jax.random.fold_in(key, 6), (16384,), 0, 2048))
    got = ops.embedding_bag(table, idx, bags, 2048)
    want = ref.embedding_bag_ref(table, idx, bags, 2048)
    ok = np.allclose(got, want, atol=1e-3)
    us = time_call(jax.jit(
        lambda t, i, b: ref.embedding_bag_ref(t, i, b, 2048)), table, idx, bags)
    record("embedding_bag", ok, us, f"allclose={ok}")

    # ------------------------------------------------------------------
    # PR 7: quantized-LUT scan, fused LUT build, streaming merge, and the
    # Engine fused-refresh trace — each with a roofline prediction booked.
    # ------------------------------------------------------------------

    # adc_lookup with the int8/uint8 LUT pack @ the same scan shape. Parity
    # (kernel == ref on the pack), quality (top-10 vs f32), and the modeled
    # scan-traffic reduction the pack buys (the ≥2× acceptance bar).
    b, Dp, K, N, blk = 8, 64, 256, 65536, 1024
    codes8 = codes.astype(jnp.uint8)
    qlut, scales = ops.quantize_luts(lut, lut_dtype)
    got_q = ops.adc_lookup(qlut, codes8, scales)
    want_q = ref.adc_lookup_ref(qlut, codes8, scales)
    base = ref.adc_lookup_ref(lut, codes8)
    agree = _topk_agree(
        jax.lax.top_k(got_q, 10)[1], jax.lax.top_k(base, 10)[1])
    bytes_f32 = analysis.adc_scan_traffic(
        b, Dp, K, N // blk, blk, "float32", luts_per_step=b)
    bytes_q = analysis.adc_scan_traffic(
        b, Dp, K, N // blk, blk, lut_dtype, luts_per_step=b)
    ratio = bytes_f32 / bytes_q
    ok = (np.allclose(got_q, want_q, atol=1e-3) and agree >= 0.9
          and ratio >= 2.0)
    us = time_call(jax.jit(lambda l, c, s: ref.adc_lookup_ref(l, c, s)),
                   qlut, codes8, scales)
    pred = analysis.kernel_predicted(b * N * Dp + 2 * b * Dp * K, bytes_q)
    record(f"adc_lookup_{lut_dtype}", ok, us,
           f"top10_agree={agree:.2f} bytes_ratio={ratio:.2f}x",
           topk_agree=agree, bytes_ratio=float(ratio),
           predicted_us=pred["predicted_us"], bytes_model=pred["bytes"])

    # fused rotation-aware LUT build @ (b=8, n=512, Dp=64, K=256, sub=8):
    # the delta hits the query block inside the tile body, so refresh never
    # touches corpus-side buffers. Parity vs the jnp oracle + prediction.
    n, sub = 512, 8
    Qf = jax.random.normal(jax.random.fold_in(key, 7), (8, n))
    qdelta = jax.random.normal(jax.random.fold_in(key, 8), (n, n)) / np.sqrt(n)
    cbf = jax.random.normal(jax.random.fold_in(key, 9), (Dp, K, sub))
    colmap = jnp.eye(Dp)
    got_f = ops.fused_lut(Qf, qdelta, cbf, colmap)
    want_f = ref.fused_lut_ref(Qf, qdelta, cbf, colmap)
    ok = np.allclose(got_f, want_f, atol=1e-3)
    us = time_call(jax.jit(ref.fused_lut_ref), Qf, qdelta, cbf, colmap)
    pred = analysis.kernel_predicted(
        2 * 8 * n * n + 2 * 8 * Dp * K * sub,
        analysis.fused_lut_traffic(8, n, Dp, K, sub))
    record("fused_lut", ok, us, f"allclose={ok}",
           predicted_us=pred["predicted_us"], bytes_model=pred["bytes"])

    # streaming top-k merge: tile-order invariance of the fold the
    # double-buffered exact scan uses (the recall oracle past HBM).
    sc = jax.random.normal(jax.random.fold_in(key, 10), (8, 16384))
    tiles = [(sc[:, i:i + 2048], jnp.arange(i, i + 2048, dtype=jnp.int32))
             for i in range(0, 16384, 2048)]
    s1, i1 = ref.streaming_topk_ref([t[0] for t in tiles],
                                    [t[1] for t in tiles], 10)
    perm = list(reversed(range(len(tiles))))
    s2, i2 = ref.streaming_topk_ref([tiles[p][0] for p in perm],
                                    [tiles[p][1] for p in perm], 10)
    _, oneshot = jax.lax.top_k(sc, 10)
    ok = (bool(jnp.array_equal(i1, i2)) and bool(jnp.array_equal(s1, s2))
          and bool(jnp.array_equal(jnp.sort(i1), jnp.sort(oneshot))))
    us = time_call(
        jax.jit(lambda s: ref.streaming_topk_ref(
            [s[:, i:i + 2048] for i in range(0, 16384, 2048)],
            [jnp.arange(i, i + 2048, dtype=jnp.int32)
             for i in range(0, 16384, 2048)], 10)[0]), sc)
    record("stream_merge", ok, us, f"tile_order_invariant={ok}")

    # Engine fused-refresh trace @ a small live index: a within-subspace
    # delta must cost zero recompiles and zero LUT-cache invalidations,
    # and the post-refresh search must reuse every cached LUT row.
    import time as _time
    from repro import search
    dim, nrows = 64, 4096
    Xs = jax.random.normal(jax.random.fold_in(key, 11), (nrows, dim))
    Rs = rotations.random_rotation(jax.random.fold_in(key, 12), dim)
    cfg = search.SearchConfig(subspaces=8, codewords=16,
                              lut_dtype=lut_dtype, fused_refresh=True)
    searcher = search.make("flat_adc")
    state = searcher.build(jax.random.PRNGKey(2), Xs, Rs, cfg)
    eng = search.Engine(searcher, state, k=10, min_bucket=4)
    Qs = np.asarray(
        jax.random.normal(jax.random.fold_in(key, 13), (8, dim)))
    eng.search(Qs)
    compiles0 = eng.stats()["compiles"]
    learner = rotations.make("subspace_gcd", sub=dim // 8)
    G = jax.random.normal(jax.random.fold_in(key, 14), (dim, dim))
    _, delta = learner.update(learner.init_from(Rs), G, 1e-3,
                              jax.random.PRNGKey(5))
    t0 = _time.perf_counter()
    eng.refresh(delta)
    refresh_us = (_time.perf_counter() - t0) * 1e6
    eng.search(Qs)
    st = eng.stats()
    ok = (st["compiles"] == compiles0 and st["lut_invalidations"] == 0
          and st["lut_hits"] >= 8)
    record("fused_refresh", ok, refresh_us,
           f"recompiles=0:{st['compiles'] == compiles0} "
           f"lut_invalidations={st['lut_invalidations']} "
           f"lut_hits={st['lut_hits']}",
           compiles=int(st["compiles"]),
           lut_invalidations=int(st["lut_invalidations"]),
           lut_hits=int(st["lut_hits"]))
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="BENCH_kernels.json destination dir "
                         "(default $REPRO_BENCH_DIR; unset → print only)")
    ap.add_argument("--lut-dtype", default="int8", choices=("int8", "uint8"),
                    help="quantized-LUT pack the int8 sections exercise")
    args = ap.parse_args()
    results = run(lut_dtype=args.lut_dtype)
    from repro import obs
    from benchmarks.run import resolve_bench_dir

    out_dir = resolve_bench_dir(args.out)
    if out_dir:
        path = obs.write_bench(
            out_dir, "kernels", sections={"kernels": results},
            checks={f"kernels/{k}": v["ok"] for k, v in results.items()})
        print(f"# BENCH written: {path}")
    bad = [k for k, v in results.items() if not v["ok"]]
    if bad:  # CI gate: an int8 parity / bytes-ratio regression fails the job
        print(f"# FAILED: {bad}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
