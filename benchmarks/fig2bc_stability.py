"""Fig 2b/c reproduction: convergence stability across runs × data sizes.

The paper's claim: GCD-G converges with significantly LOWER variance than
OPQ across repeated runs, and degrades more gracefully on small data
fractions (it "works better in the stochastic descent scenario").
We sweep data fractions {10%, 50%, 100%} × `runs` seeds and compare the
run-to-run std of the final distortion.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro import quant
from repro.data import synthetic


def run(num=4096, dim=64, D=8, K=32, iters=20, runs=5, verbose=True):
    cfg = quant.PQConfig(D, K)
    X_full = synthetic.sift_like(jax.random.PRNGKey(0), num, dim)
    out = {}
    for frac in (0.1, 0.5, 1.0):
        n = int(num * frac)
        finals = {"procrustes": [], "gcd_greedy": []}
        for r in range(runs):
            Xr = X_full[
                np.random.RandomState(r).permutation(num)[:n]
            ]
            for solver in finals:
                _R, _cb, trace = quant.opq.alternating_minimization(
                    jax.random.PRNGKey(100 + r), Xr, cfg, iters=iters,
                    rotation=solver, inner_steps=5, lr=2e-3,
                )
                finals[solver].append(float(np.asarray(trace)[-1]))
        stats = {
            s: {"mean": float(np.mean(v)), "std": float(np.std(v))}
            for s, v in finals.items()
        }
        out[frac] = stats
        if verbose:
            for s in stats:
                emit(f"fig2bc/frac{int(frac*100)}/{s}", 0.0,
                     f"mean={stats[s]['mean']:.4f};std={stats[s]['std']:.4f}")
    # paper claim: GCD-G std <= OPQ std (lower variance)
    stable = all(out[f]["gcd_greedy"]["std"] <= out[f]["procrustes"]["std"] * 1.5
                 for f in out)
    if verbose:
        emit("fig2bc/check/gcd_more_stable", 0.0, str(stable))
    return out, stable


if __name__ == "__main__":
    run()
