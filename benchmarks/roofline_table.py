"""§Roofline aggregation: read experiments/dryrun/*.json into the 40-cell
table (arch × shape × mesh → three terms + dominant + useful-compute ratio).

Emits CSV rows and can render the EXPERIMENTS.md markdown table.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load(dryrun_dir: str = DRYRUN_DIR) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(verbose: bool = True, dryrun_dir: str = DRYRUN_DIR):
    recs = load(dryrun_dir)
    ok = [r for r in recs if r.get("ok")]
    for r in ok:
        rep = r["report"]
        if verbose:
            emit(
                f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                f"compute={rep['compute_s']:.3e};memory={rep['memory_s']:.3e};"
                f"collective={rep['collective_s']:.3e};dominant={rep['dominant']};"
                f"fraction={rep['roofline_fraction']:.3f};"
                f"peakGiB={rep['memory']['peak_bytes']/2**30:.2f}",
            )
    if verbose:
        emit("roofline/summary", 0.0,
             f"cells_ok={len(ok)};cells_failed={len(recs)-len(ok)}")
    return recs


def markdown_table(dryrun_dir: str = DRYRUN_DIR, mesh: str = "16x16") -> str:
    recs = [r for r in load(dryrun_dir) if r.get("mesh") == mesh]
    lines = [
        "| arch | shape | FLOPs/dev | compute s | memory s | collective s |"
        " dominant | useful ratio | peak GiB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED:"
                         f" {r.get('error','?')[:60]} | | | | | | | |")
            continue
        rep = r["report"]
        ratio = rep.get("useful_compute_ratio", float("nan"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rep['flops_per_device']:.2e} |"
            f" {rep['compute_s']:.2e} | {rep['memory_s']:.2e} |"
            f" {rep['collective_s']:.2e} | {rep['dominant']} |"
            f" {ratio:.2f} | {rep['memory']['peak_bytes']/2**30:.2f} |"
            f" {'yes' if r.get('fits_hbm') else 'NO'} |"
        )
    return "\n".join(lines)


BENCH_FAST = os.path.join(os.path.dirname(__file__), "BENCH_fast.json")


def kernel_table(bench_path: str = BENCH_FAST) -> str:
    """Predicted-vs-measured table for the scan-hot-path kernels (PR 7).

    Reads the latest booked ``kernels`` section of a BENCH trajectory:
    ``predicted_us`` is the TPU roofline bound from the modeled grid traffic
    (``roofline.analysis.kernel_predicted``); ``us_per_call`` is the measured
    wall-clock of the XLA-oracle path on the machine that ran the bench (CPU
    in this container — the two columns are booked side by side, not
    compared)."""
    from repro.obs import bench as obs_bench

    doc = obs_bench.load_bench(bench_path)
    kernels = {}
    for run_ in doc["runs"]:  # latest run wins
        sec = run_.get("sections", {}).get("kernels")
        if sec:
            kernels = sec
    lines = [
        "| kernel | ok | measured µs | predicted µs (TPU) | model bytes |"
        " detail |",
        "|---|---|---|---|---|---|",
    ]
    for name in sorted(kernels):
        v = kernels[name]
        pred = (f"{v['predicted_us']:.1f}" if "predicted_us" in v else "—")
        byts = (f"{v['bytes_model']/2**20:.2f} MiB"
                if "bytes_model" in v else "—")
        extra = []
        if "bytes_ratio" in v:
            extra.append(f"bytes moved ÷{v['bytes_ratio']:.2f} vs f32 LUTs")
        if "topk_agree" in v:
            extra.append(f"top-10 agree {v['topk_agree']:.2f}")
        if "lut_invalidations" in v:
            extra.append(f"refresh: {v['lut_invalidations']} LUT rebuilds, "
                         f"{v.get('lut_hits', 0)} cached rows reused")
        lines.append(
            f"| {name} | {'yes' if v.get('ok') else 'NO'} |"
            f" {v.get('us_per_call', float('nan')):.1f} | {pred} | {byts} |"
            f" {'; '.join(extra)} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    if "--kernels" in sys.argv:
        print(kernel_table())
    else:
        run()
