"""Fig 2a reproduction: fixed-embedding distortion convergence (paper §3.1).

OPQ (SVD/Procrustes) vs Cayley-SGD vs GCD-R / GCD-G / GCD-S vs the
overlapping ablations on a SIFT-like anisotropic mixture. CPU-sized:
N=4096, n=64, D=8, K=32. The solver list is the ``repro.rotations``
registry — a learner registered there is automatically swept here.

Paper claims checked:
  * GCD-G and GCD-S converge comparably to OPQ;
  * overlapping GCD-G does NOT converge well (disjointness matters);
  * GCD-R trails GCD-G (steeper directions matter);
  * Cayley converges slower than GCD-G.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro import quant, rotations
from repro.data import synthetic

# every registered learner except subspace_gcd (needs a serving index's
# subspace width — it is exercised by the ivf benchmark instead)
SOLVERS = [n for n in rotations.names() if n != "subspace_gcd"]
# lr swept in {2e-3 … 1e-1} × inner {5, 15}: 3e-2/5 converges fastest and
# stays stable; ≥1e-1 diverges (EXPERIMENTS.md §Paper-claims note).
# GCD-S takes 2e-2: its heavier matchings overshoot at 3e-2 (the total
# |step| per iteration is larger than greedy's at equal lr).
LRS = {"cayley_sgd": 3e-4, "gcd_random": 3e-2, "gcd_greedy": 3e-2,
       "gcd_steepest": 2e-2, "gcd_overlap_random": 3e-2,
       "gcd_overlap_greedy": 3e-2}


def run(num=4096, dim=64, D=8, K=32, iters=25, inner=5, seed=0, verbose=True):
    X = synthetic.sift_like(jax.random.PRNGKey(seed), num, dim)
    cfg = quant.PQConfig(D, K)
    results = {}
    for solver in SOLVERS:
        t0 = time.perf_counter()
        _R, _cb, trace = quant.opq.alternating_minimization(
            jax.random.PRNGKey(seed + 1), X, cfg, iters=iters,
            rotation=solver, inner_steps=inner,
            lr=LRS.get(solver, 1e-3),
        )
        trace = np.asarray(jax.block_until_ready(trace))
        dt = (time.perf_counter() - t0) * 1e6 / iters
        results[solver] = {"trace": trace, "final": float(trace[-1]),
                           "us_per_iter": dt}
        if verbose:
            emit(f"fig2a/{solver}", dt, f"final_distortion={trace[-1]:.4f}")
    r = results
    checks = {
        "gcd_g_close_to_opq": r["gcd_greedy"]["final"]
        <= 1.10 * r["procrustes"]["final"],
        "gcd_s_close_to_opq": r["gcd_steepest"]["final"]
        <= 1.10 * r["procrustes"]["final"],
        "gcd_g_beats_overlap_g": r["gcd_greedy"]["final"]
        <= r["gcd_overlap_greedy"]["final"] + 1e-6,
        "gcd_g_beats_random": r["gcd_greedy"]["final"]
        <= r["gcd_random"]["final"] + 1e-6,
        "gcd_g_beats_cayley": r["gcd_greedy"]["final"]
        <= r["cayley_sgd"]["final"] + 1e-6,
        "all_beat_frozen": max(r[s]["final"] for s in
                               ("procrustes", "gcd_greedy", "gcd_steepest"))
        < r["frozen"]["final"],
    }
    if verbose:
        for k, v in checks.items():
            emit(f"fig2a/check/{k}", 0.0, str(v))
    return results, checks


if __name__ == "__main__":
    run()
